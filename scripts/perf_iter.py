"""§Perf iteration runner: recompile one cell with knob overrides and diff
its roofline terms against the stored baseline.

Usage:
  PYTHONPATH=src:. python scripts/perf_iter.py --arch grok-1-314b \
      --shape train_4k --set REPRO_REMAT=dots [--unroll] [--tag dots]

Writes reports/perf/<arch>__<shape>__<tag>.json and prints the delta table.
"""

import argparse
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9


def terms(pd):
    return {
        "compute_s": (pd["flops"] or 0) / PEAK_FLOPS,
        "memory_s": (pd["bytes_accessed"] or 0) / HBM_BW,
        "collective_s": pd["collective_bytes"]["total"] / ICI_BW,
        "temp_gb": pd["temp_bytes"] / 2**30,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", nargs="*", default=[], help="ENV=VALUE knobs")
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--tag", required=True)
    args = ap.parse_args()

    env = dict(os.environ)
    for kv in args.set:
        k, v = kv.split("=", 1)
        env[k] = v
    env["PYTHONPATH"] = "src"

    outdir = "reports/perf"
    os.makedirs(outdir, exist_ok=True)
    tmpdir = os.path.join(outdir, f"_tmp_{args.tag}")
    os.makedirs(tmpdir, exist_ok=True)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
           "--shape", args.shape, "--out-dir", tmpdir]
    if args.unroll:
        cmd.append("--unroll")
    subprocess.run(cmd, env=env, check=True)

    suffix = "pod_unrolled" if args.unroll else "pod"
    got = json.load(open(os.path.join(
        tmpdir, f"{args.arch}__{args.shape}__{suffix}.json")))
    final = os.path.join(outdir, f"{args.arch}__{args.shape}__{args.tag}.json")
    got["knobs"] = args.set
    json.dump(got, open(final, "w"), indent=2)

    base_path = f"reports/dryrun/{args.arch}__{args.shape}__{suffix}.json"
    if os.path.exists(base_path):
        base = json.load(open(base_path))
        bt, gt = terms(base["per_device"]), terms(got["per_device"])
        print(f"\n{'term':14s}{'baseline':>12s}{'this':>12s}{'delta':>9s}")
        for k in bt:
            d = (gt[k] - bt[k]) / bt[k] * 100 if bt[k] else float("nan")
            print(f"{k:14s}{bt[k]:12.4f}{gt[k]:12.4f}{d:8.1f}%")
    print("\nwrote", final)


if __name__ == "__main__":
    main()
