"""Regenerate the §Dry-run and §Roofline markdown tables from
reports/dryrun/*.json into reports/tables/. EXPERIMENTS.md embeds these.

Usage: PYTHONPATH=src:. python scripts/make_tables.py
"""

import json
import glob
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import load_cells, model_flops, roofline_row  # noqa


def gb(x):
    return f"{x / 2**30:.2f}"


def dryrun_table() -> str:
    rows = []
    for f in sorted(glob.glob("reports/dryrun/*__pod.json")) + \
            sorted(glob.glob("reports/dryrun/*__multi.json")):
        d = json.load(open(f))
        mesh = "2x16x16" if "__multi" in f else "16x16"
        if d["status"] == "skipped":
            rows.append(f"| {d['arch']} | {d['shape']} | {mesh} | skipped "
                        f"(full attention; DESIGN §5) | | | | |")
            continue
        pd = d["per_device"]
        state_gb = gb(pd["argument_bytes"])
        temp_gb = gb(pd["temp_bytes"])
        fits = "yes" if (pd["argument_bytes"] + pd["temp_bytes"]
                         + pd["output_bytes"]) < 16 * 2**30 else "NO"
        coll = pd["collective_bytes"]
        cc = coll["counts"]
        collstr = "/".join(str(cc[k]) for k in
                           ("all-gather", "all-reduce", "reduce-scatter",
                            "all-to-all", "collective-permute"))
        rows.append(
            f"| {d['arch']} | {d['shape']} | {mesh} | ok "
            f"({d['compile_s']:.0f}s) | {state_gb} | {temp_gb} | {fits} "
            f"| {collstr} |")
    head = ("| arch | shape | mesh | compile | state GiB/dev | temp GiB/dev "
            "| fits 16 GiB | colls ag/ar/rs/a2a/cp |\n"
            "|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def roofline_table() -> str:
    rows = []
    for r in [roofline_row(d) for _, d in sorted(load_cells().items())]:
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
            f"| **{r['dominant']}** | {r['model_flops']:.3g} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['cost_source']} |")
    head = ("| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL_FLOPS | useful ratio | roofline frac | src |\n"
            "|---|---|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


if __name__ == "__main__":
    os.makedirs("reports/tables", exist_ok=True)
    with open("reports/tables/dryrun.md", "w") as f:
        f.write(dryrun_table() + "\n")
    with open("reports/tables/roofline.md", "w") as f:
        f.write(roofline_table() + "\n")
    print("wrote reports/tables/{dryrun,roofline}.md")
