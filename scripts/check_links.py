"""Docs link check: every relative markdown link must resolve.

Scans README.md, benchmarks/README.md, and everything under docs/ for
inline markdown links ``[text](target)``; fails (exit 1, one line per
problem) when a relative target does not exist on disk or when an anchor
(``file.md#section`` or ``#section``) names no heading in the target file.
External links (http/https/mailto) are not fetched — this guard is about
the repo's own doc tree staying navigable as files move across PRs.

Anchors are matched GitHub-style: heading text lowercased, punctuation
stripped, spaces to dashes (duplicate headings get ``-1``, ``-2``, ...).

    python scripts/check_links.py            # default file set
    python scripts/check_links.py FILE...    # explicit files/dirs
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DEFAULT_TARGETS = ["README.md", "docs", "benchmarks/README.md"]

# inline links, skipping images; stop at the first unescaped ')'
LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def heading_anchors(md_path: Path) -> set:
    """GitHub-style anchor slugs for every heading in ``md_path``."""
    anchors: set = set()
    counts: dict = {}
    in_fence = False
    for line in md_path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence or not line.startswith("#"):
            continue
        text = line.lstrip("#").strip()
        # strip markdown emphasis/code markers (not underscores — GitHub
        # keeps them in slugs), then non-word punctuation
        text = re.sub(r"[*`]", "", text)
        slug = re.sub(r"[^\w\- ]", "", text.lower()).strip()
        slug = re.sub(r"\s+", "-", slug)
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def md_files(targets):
    """Resolve targets to markdown files; a missing target is itself a
    problem (a renamed README/docs tree must fail the check, not shrink
    its coverage silently). Returns (files, problems)."""
    files, problems = [], []
    for t in targets:
        p = (ROOT / t) if not Path(t).is_absolute() else Path(t)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            problems.append(f"missing check target {t!r}")
    return files, problems


def check_file(md: Path) -> list:
    problems = []
    in_fence = False
    for lineno, line in enumerate(
            md.read_text(encoding="utf-8").splitlines(), 1):
        if CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:
                continue
            path_part, _, anchor = target.partition("#")
            dest = (md.parent / path_part).resolve() if path_part else md
            rel = md.relative_to(ROOT)
            if path_part and not dest.exists():
                problems.append(
                    f"{rel}:{lineno}: broken link {target!r} "
                    f"(no such file {path_part!r})")
                continue
            if anchor:
                if dest.is_dir() or dest.suffix.lower() != ".md":
                    problems.append(
                        f"{rel}:{lineno}: anchor on non-markdown "
                        f"target {target!r}")
                elif anchor.lower() not in heading_anchors(dest):
                    problems.append(
                        f"{rel}:{lineno}: dangling anchor {target!r} "
                        f"(no heading '#{anchor}' in "
                        f"{dest.relative_to(ROOT)})")
    return problems


def main(argv=None) -> int:
    targets = (argv if argv else DEFAULT_TARGETS)
    files, problems = md_files(targets)
    for md in files:
        problems.extend(check_file(md))
    for p in problems:
        print(p)
    print(f"checked {len(files)} file(s): "
          f"{'OK' if not problems else f'{len(problems)} problem(s)'}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
