"""Quickstart: declare a PTG once, run it on both back-ends.

TaskTorrent's one-API story through the unified ``repro.ptg`` front-end:

1. Declare the graph — task types with index spaces plus the blocks each
   task reads/writes and an owner mapping. ``in_deps``/``out_deps``/
   ``operands``/``indegree``/seeds are all *derived* (mutual inverses by
   construction — no hand-written edge functions to get wrong).
2. Lower the SAME definition to
   (a) the host runtime: async Taskflow + one-sided active messages
       generated from the derived out-edges (the paper's §II-A3 program);
   (b) the compiled executor: parallel DAG discovery -> wavefront schedule
       -> shard_map with classified sparse/dense collective exchanges.

Run: PYTHONPATH=src python examples/quickstart.py
(set XLA_FLAGS=--xla_force_host_platform_device_count=4 for real sharding
in the compiled half).
"""

import numpy as np

from repro.ptg import Graph


def declare_chain(n_ranks: int, chain: int) -> Graph:
    """A ring of accumulating tasks: task k reads block k-1, writes block
    k, on rank k mod n_ranks — every hand-off is a cross-rank active
    message on the host backend, a ppermute on the compiled one."""
    g = Graph("chain", n_shards=n_ranks, owner=lambda blk: blk[1] % n_ranks,
              block_shape=(1, 1))
    g.task_type("acc",
                space=lambda: ((k,) for k in range(chain)),
                writes=lambda k: ("v", k),
                reads=lambda k: [("v", k - 1)] if k else [])
    return g


def host_runtime_demo():
    n_ranks, chain = 3, 12
    g = declare_chain(n_ranks, chain)
    # derived structure: one seed, a pure chain
    assert g.seeds == [("acc", 0)]
    assert g.out_deps(("acc", 4)) == [("acc", 5)]

    blocks = {("v", k): np.zeros((1, 1)) for k in range(chain)}
    bodies = {"acc": lambda *prev: (prev[0] if prev else 0.0) + 1.0}
    out = g.run_host(blocks, bodies, n_threads=2)
    total = float(out[("v", chain - 1)])
    assert total == chain, total
    print(f"[host runtime] chain of {chain} tasks across {n_ranks} ranks: "
          f"final value {total:.0f} (one AM per hand-off)")


def compiled_backend_demo():
    import jax
    import jax.numpy as jnp

    from repro.linalg.cholesky import (assemble_lower, cholesky_bodies,
                                       cholesky_graph, make_spd_blocks)
    from repro.linalg.host_exec import as_numpy_bodies

    n_dev = len(jax.devices())
    pr = 2 if n_dev >= 2 else 1
    pc = 2 if n_dev >= 4 else 1
    nb, b = 4, 16
    # ONE declarative definition (4 task types + reads/writes accesses)...
    graph = cholesky_graph(nb, pr, pc, b)
    blocks, a = make_spd_blocks(nb, b)

    # ...two lowerings. (a) host runtime:
    host = graph.run_host(blocks, as_numpy_bodies(cholesky_bodies()))
    l_host = assemble_lower(host, nb, b)

    # (b) compiled SPMD executor:
    prog = graph.to_program()
    mesh = jax.sharding.Mesh(np.array(jax.devices()[: pr * pc]), ("shards",))
    with mesh:
        run = jax.jit(prog.auto_executor(cholesky_bodies(), mesh))
        comp = prog.unpack(run(jnp.asarray(prog.pack(blocks))))
    l_comp = assemble_lower(comp, nb, b)

    err = np.abs(l_comp @ l_comp.T - a).max()
    agree = np.abs(l_comp - l_host).max()
    print(f"[one graph, two backends] {nb}x{nb}-block Cholesky on "
          f"{pr * pc} shard(s): |LL^T - A|_max = {err:.2e}, "
          f"|host - compiled|_max = {agree:.2e}")
    stats = prog.comm_stats(comm="auto")
    print(f"  schedule: {prog.schedule.n_wavefronts} wavefronts, "
          f"{stats['real_bytes'] / 1e3:.1f} KB on the wire, efficiency "
          f"{stats['wire_efficiency']:.2f} (classified sparse exchange)")


if __name__ == "__main__":
    host_runtime_demo()
    compiled_backend_demo()
