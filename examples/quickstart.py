"""Quickstart: TaskTorrent's two halves in ~80 lines.

1. The host runtime — the paper's §II-A3 example: a distributed PTG where
   task k's output is shipped to the rank owning task k+1 via an active
   message that stores the payload and fulfills the promise.
2. The compiled backend — the same PTG idea lowered to a lockstep SPMD
   program (here: a tiny distributed Cholesky through shard_map on however
   many host devices are available; run with
   XLA_FLAGS=--xla_force_host_platform_device_count=4 for real sharding).

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import run_ranks


def host_runtime_demo():
    n_ranks, chain = 3, 12

    def main(ctx):
        data = {}
        tf = ctx.taskflow("chain")
        am = {}

        tf.set_indegree(lambda k: 1)
        tf.set_mapping(lambda k: k % ctx.tp.n_threads)

        def body(k):
            value = data.get(k, 0) + k          # "compute"
            dest_rank = (k + 1) % ctx.n_ranks
            if k + 1 < chain:
                if dest_rank == ctx.rank:
                    data[k + 1] = value
                    tf.fulfill_promise(k + 1)
                else:                            # one-sided active message
                    am["am"].send(dest_rank, k + 1, value)

        tf.set_task(body)
        am["am"] = ctx.comm.make_active_msg(
            lambda k, v: (data.__setitem__(k, v), tf.fulfill_promise(k)))

        if ctx.rank == 0:
            data[0] = 0
            tf.fulfill_promise(0)
        ctx.tp.join()                            # distributed completion
        return data

    results = run_ranks(n_ranks, main, n_threads=2)
    total = {k: v for r in results for k, v in r.items()}
    assert total[chain - 1] == sum(range(chain - 1)), total
    print(f"[host runtime] chain of {chain} tasks across {n_ranks} ranks: "
          f"final value {total[chain - 1]} (= sum 0..{chain - 2})")


def compiled_backend_demo():
    import jax
    import jax.numpy as jnp

    from repro.linalg.cholesky import (assemble_lower, cholesky_executor,
                                       cholesky_program, make_spd_blocks)

    n_dev = len(jax.devices())
    pr = 2 if n_dev >= 2 else 1
    pc = 2 if n_dev >= 4 else 1
    nb, b = 4, 16
    prog = cholesky_program(nb, pr, pc, b)
    blocks, a = make_spd_blocks(nb, b)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[: pr * pc]), ("shards",))
    with mesh:
        run = jax.jit(cholesky_executor(prog, mesh))
        out = prog.unpack(run(jnp.asarray(prog.pack(blocks))))
    l = assemble_lower(out, nb, b)
    err = np.abs(l @ l.T - a).max()
    print(f"[compiled backend] {nb}x{nb}-block Cholesky on {pr * pc} "
          f"shard(s): |LL^T - A|_max = {err:.2e}")
    stats = prog.comm_stats(comm="auto")
    print(f"  schedule: {prog.schedule.n_wavefronts} wavefronts, "
          f"{stats['real_bytes'] / 1e3:.1f} KB on the wire, efficiency "
          f"{stats['wire_efficiency']:.2f} (classified sparse exchange)")


if __name__ == "__main__":
    host_runtime_demo()
    compiled_backend_demo()
