"""The paper's flagship app end-to-end: distributed blocked Cholesky
declared ONCE via the unified ``repro.ptg`` front-end and executed on BOTH
backends from that single definition —

  (a) the host TaskTorrent runtime: async tasks + work stealing + one-sided
      active messages + distributed completion detection;
  (b) the compiled SPMD executor: parallel DAG discovery -> wavefront
      schedule -> shard_map with classified sparse/dense exchanges.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=4 to see real
multi-device sharding in (b).

  PYTHONPATH=src python examples/distributed_cholesky.py --nb 8 --block 32
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.linalg.cholesky import (assemble_lower, cholesky_executor,
                                   cholesky_graph, make_spd_blocks)


def np_bodies():
    return {
        "potrf": lambda a: np.linalg.cholesky(a),
        "trsm": lambda a, l_kk: np.linalg.solve(l_kk, a.T).T,
        "syrk": lambda a, l: a - l @ l.T,
        "gemm": lambda a, li, lj: a - li @ lj.T,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nb", type=int, default=8)
    ap.add_argument("--block", type=int, default=32)
    ap.add_argument("--grid", type=int, nargs=2, default=(2, 2))
    args = ap.parse_args()
    pr, pc = args.grid
    nb, b = args.nb, args.block
    n = nb * b

    graph = cholesky_graph(nb, pr, pc, b)   # ONE declarative definition
    blocks, a = make_spd_blocks(nb, b)
    want = np.linalg.cholesky(a)

    # (a) host runtime, wired from the derived out-edges
    t0 = time.perf_counter()
    host = graph.run_host(blocks, np_bodies(), n_threads=2)
    t_host = time.perf_counter() - t0
    l_host = assemble_lower(host, nb, b)
    print(f"[host runtime]  N={n} on {pr}x{pc} ranks: {t_host * 1e3:7.1f} ms  "
          f"max|err|={np.abs(l_host - want).max():.2e}")

    # (b) compiled backend: classified sparse exchange + comm/compute overlap
    prog = graph.to_program()
    n_dev = len(jax.devices())
    if n_dev < pr * pc:
        print(f"[compiled]      only {n_dev} device(s): set XLA_FLAGS="
              f"--xla_force_host_platform_device_count={pr * pc} "
              "for real sharding; running anyway if possible")
    mesh = jax.sharding.Mesh(
        np.array((jax.devices() * (pr * pc))[: pr * pc]), ("shards",)) \
        if n_dev < pr * pc else jax.sharding.Mesh(
            np.array(jax.devices()[: pr * pc]), ("shards",))
    if n_dev >= pr * pc:
        with mesh:
            run = jax.jit(cholesky_executor(prog, mesh))
            out = prog.unpack(run(jnp.asarray(prog.pack(blocks))))  # warmup
            t0 = time.perf_counter()
            out = prog.unpack(
                jax.block_until_ready(run(jnp.asarray(prog.pack(blocks)))))
            t_comp = time.perf_counter() - t0
        l_comp = assemble_lower(out, nb, b)
        print(f"[compiled SPMD] N={n} on {pr * pc} shards: "
              f"{t_comp * 1e3:7.1f} ms  "
              f"max|err|={np.abs(l_comp - want).max():.2e}")
    st = prog.comm_stats(comm="auto")
    dense = prog.comm_stats(comm="dense")
    print(f"schedule: {prog.schedule.n_wavefronts} wavefronts | wire "
          f"{st['real_bytes'] / 1e6:.2f} MB real / "
          f"{st['padded_bytes'] / 1e6:.2f} MB padded "
          f"(efficiency {st['wire_efficiency']:.2f} vs "
          f"{dense['wire_efficiency']:.2f} dense all_to_all)")


if __name__ == "__main__":
    main()
