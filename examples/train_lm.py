"""End-to-end training driver: model + optimizer + deterministic data +
async checkpointing + restart, on any --arch from the registry.

Defaults train a reduced config on a *learnable* synthetic task (arithmetic
progressions mod vocab) so the loss demonstrably falls on CPU in minutes.
On hardware, pass --full for the exact published config and point --data at
a packed uint32 token file.

  PYTHONPATH=src python examples/train_lm.py --arch qwen3-14b --steps 60
  PYTHONPATH=src python examples/train_lm.py --arch yi-6b --resume ...
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.train import checkpoint as ckpt
from repro.train.data import PackedBinaryDataset, SyntheticLM
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="published config (hardware scale)")
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--d-ff", type=int, default=None)
    ap.add_argument("--data", default=None, help="packed uint32 token file")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        overrides = {}
        if args.d_model:
            overrides.update(d_model=args.d_model, d_head=args.d_model // 8,
                             n_heads=8, n_kv_heads=4)
        if args.layers:
            overrides["n_layers"] = args.layers
        if args.vocab:
            overrides["vocab_size"] = args.vocab
        if args.d_ff:
            overrides["d_ff"] = args.d_ff
        cfg = reduced(cfg, **overrides)
    print(f"arch={cfg.name} params={cfg.n_params() / 1e6:.1f}M "
          f"(active {cfg.n_active_params() / 1e6:.1f}M) opt={cfg.optimizer}")

    if args.data:
        ds = PackedBinaryDataset(args.data, args.seq, args.batch)
    else:
        ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                         embed_dim=cfg.d_model if cfg.embed_inputs else None,
                         encdec=cfg.family == "encdec", learnable=True)

    params, opt_state = init_train_state(cfg, jax.random.key(0))
    start = 0
    latest = ckpt.latest_step(args.ckpt_dir)
    if latest is not None:
        print(f"resuming from checkpoint step {latest}")
        state = ckpt.restore(args.ckpt_dir, latest,
                             {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = latest

    step_fn = jax.jit(make_train_step(cfg, lr=args.lr))
    saver = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=2)

    t0 = time.time()
    for step in range(start, start + args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 5 == 0 or step == start + args.steps - 1:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            tok_s = (step - start + 1) * args.batch * args.seq \
                / (time.time() - t0)
            print(f"step {step:5d}  loss {loss:7.4f}  |g| {gn:8.3f}  "
                  f"{tok_s:9.0f} tok/s", flush=True)
        if step and step % args.ckpt_every == 0:
            saver.save(step, {"params": params, "opt": opt_state})
    saver.wait()  # quiesce in-flight writes before exit (completion rule)
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
