"""Serving driver: prefill a batch of prompts, then batched greedy decode
against the KV cache (GQA / MLA-latent / Mamba-state per family).

  PYTHONPATH=src python examples/serve_lm.py --arch yi-6b --tokens 32
  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-1.3b --tokens 64
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.models import transformer as tfm
from repro.serve.decode import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    print(f"serving {cfg.name} ({cfg.n_params() / 1e6:.1f}M params, "
          f"family={cfg.family})")

    params = tfm.init_params(cfg, jax.random.key(0))
    b = args.batch

    enc_out = None
    if cfg.family == "encdec":
        hd, hkv = cfg.head_dim, cfg.n_kv_heads
        enc_out = (jnp.zeros((cfg.n_layers, b, hkv, args.prompt_len, hd),
                             jnp.bfloat16),
                   jnp.zeros((cfg.n_layers, b, hkv, args.prompt_len, hd),
                             jnp.bfloat16))
    cache = tfm.init_cache(cfg, b, args.max_seq, enc_out=enc_out)

    serve_step = jax.jit(lambda p, t, c: make_serve_step(cfg)(p, t, c))

    # "prefill" by decoding the prompt tokens into the cache (simple path;
    # the bulk prefill kernel path is exercised by launch/dryrun prefill
    # cells)
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, (b, args.prompt_len),
                          dtype=np.int32)
    tok = jnp.asarray(prompt[:, 0])
    t0 = time.time()
    for i in range(1, args.prompt_len):
        _, _, cache = serve_step(params, tok, cache)
        tok = jnp.asarray(prompt[:, i])
    print(f"prefill({args.prompt_len} tokens): "
          f"{(time.time() - t0) * 1e3:.0f} ms")

    generated = []
    t0 = time.time()
    for _ in range(args.tokens):
        tok, logits, cache = serve_step(params, tok, cache)
        generated.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.stack(generated, axis=1)
    print(f"decoded {args.tokens} tokens x batch {b}: "
          f"{b * args.tokens / dt:.1f} tok/s")
    print("sample:", gen[0][:16].tolist())
    assert np.isfinite(np.asarray(logits, np.float32)).all()


if __name__ == "__main__":
    main()
